"""L2: the benchmark compute graphs, as jitted JAX functions calling the L1
Pallas kernels. `aot.py` lowers each entry of MODELS once to HLO text; the
Rust coordinator executes them from task bodies via PJRT (python never runs
at execution time).

Every function returns a tuple — the artifacts are lowered with
``return_tuple=True`` and the Rust side unwraps tuples uniformly.
"""

import jax
import jax.numpy as jnp

from . import kernels

F32 = jnp.float32


# --- Matmul task body (C += A @ B on one block) -----------------------------


def matmul_step(a, b, c):
    return (kernels.matmul_block(a, b, c),)


# --- N-Body task bodies ------------------------------------------------------


def nbody_forces_step(pos_i, pos_j, mass_j):
    return (kernels.nbody_forces(pos_i, pos_j, mass_j),)


def nbody_update_step(pos, vel, acc, dt):
    pos_new, vel_new = kernels.nbody_update(pos, vel, acc, dt[0])
    return (pos_new, vel_new)


# --- SparseLU task bodies -----------------------------------------------------


def lu0_step(a):
    return (kernels.lu0(a),)


def fwd_step(diag, a):
    return (kernels.fwd(diag, a),)


def bdiv_step(diag, a):
    return (kernels.bdiv(diag, a),)


def bmod_step(row, col, inner):
    return (kernels.bmod(row, col, inner),)


def _mat(bs):
    return jax.ShapeDtypeStruct((bs, bs), F32)


def _vec3(bs):
    return jax.ShapeDtypeStruct((bs, 3), F32)


#: name -> (function, example argument specs). Names become artifact files
#: `<name>.hlo.txt`; block sizes are fixed per artifact (one compiled
#: executable per model variant, as the runtime expects).
MODELS = {
    # E2E block size (64) and the paper's KNL-FG block size (256).
    "matmul_block": (matmul_step, (_mat(64), _mat(64), _mat(64))),
    "matmul_block_256": (matmul_step, (_mat(256), _mat(256), _mat(256))),
    # SparseLU at the e2e block size.
    "lu0": (lu0_step, (_mat(64),)),
    "fwd": (fwd_step, (_mat(64), _mat(64))),
    "bdiv": (bdiv_step, (_mat(64), _mat(64))),
    "bmod": (bmod_step, (_mat(64), _mat(64), _mat(64))),
    # N-Body at the paper's CG block size.
    "nbody_forces": (
        nbody_forces_step,
        (_vec3(128), _vec3(128), jax.ShapeDtypeStruct((128,), F32)),
    ),
    "nbody_update": (
        nbody_update_step,
        (_vec3(128), _vec3(128), _vec3(128), jax.ShapeDtypeStruct((1,), F32)),
    ),
}


# --- Fused L2 graph: one whole N-Body timestep over all blocks ---------------
#
# Demonstrates L2 composition: the Pallas force kernel is instantiated for
# every (i, j) block pair and the update kernel for every block, fused by
# XLA into one executable — the "one compiled executable per model variant"
# the runtime loads for coarse-grain offload experiments.

NB_FUSED = 4  # blocks in the fused-timestep artifact
BS_FUSED = 64  # particles per block


def nbody_timestep(pos, vel, mass, dt):
    """One timestep over `NB_FUSED` blocks.

    pos, vel: (nb, bs, 3); mass: (nb, bs); dt: (1,).
    Returns (pos', vel').
    """
    nb = pos.shape[0]
    forces = []
    for i in range(nb):
        acc_i = jnp.zeros_like(pos[i])
        for j in range(nb):
            acc_i = acc_i + kernels.nbody_forces(pos[i], pos[j], mass[j])
        forces.append(acc_i)
    acc = jnp.stack(forces)
    new_pos, new_vel = [], []
    for i in range(nb):
        p, v = kernels.nbody_update(pos[i], vel[i], acc[i], dt[0])
        new_pos.append(p)
        new_vel.append(v)
    return (jnp.stack(new_pos), jnp.stack(new_vel))


MODELS["nbody_timestep"] = (
    nbody_timestep,
    (
        jax.ShapeDtypeStruct((NB_FUSED, BS_FUSED, 3), F32),
        jax.ShapeDtypeStruct((NB_FUSED, BS_FUSED, 3), F32),
        jax.ShapeDtypeStruct((NB_FUSED, BS_FUSED), F32),
        jax.ShapeDtypeStruct((1,), F32),
    ),
)
