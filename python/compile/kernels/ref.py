"""Pure-jnp reference oracles for every L1 Pallas kernel.

These are the CORE correctness signal for the compile path: pytest (with
hypothesis shape/seed sweeps) asserts ``kernels.* ≈ ref.*``, and the Rust
end-to-end example checks the PJRT artifacts against independently computed
results.

The block operations mirror the benchmarks of the paper (§4.2): blocked
Matmul, the N-Body force/update kernels, and the four SparseLU block
kernels of the BOTS-derived benchmark (lu0 / fwd / bdiv / bmod), all
without pivoting, exactly like the original application.
"""

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Matmul (paper §4.2.1)
# ---------------------------------------------------------------------------


def matmul_block(a, b, c):
    """One Matmul task: C_new = C + A @ B on BS x BS blocks."""
    return c + a @ b


# ---------------------------------------------------------------------------
# N-Body (paper §4.2.2)
# ---------------------------------------------------------------------------

SOFTENING = 1e-3


def nbody_forces(pos_i, pos_j, mass_j):
    """Accelerations on block i from block j (softened gravity, G = 1).

    pos_i: (bs, 3), pos_j: (bs, 3), mass_j: (bs,) -> (bs, 3).
    """
    d = pos_j[None, :, :] - pos_i[:, None, :]
    dist2 = jnp.sum(d * d, axis=-1) + SOFTENING
    inv_d3 = dist2 ** (-1.5)
    return jnp.einsum("pq,pqc,q->pc", inv_d3, d, mass_j)


def nbody_update(pos, vel, acc, dt):
    """Integration for one particle block. Returns (pos', vel')."""
    vel_new = vel + acc * dt
    pos_new = pos + vel_new * dt
    return pos_new, vel_new


# ---------------------------------------------------------------------------
# Sparse LU block kernels (paper §4.2.3) — no pivoting, like BOTS.
# ---------------------------------------------------------------------------


def lu0(a):
    """In-block LU factorization (Doolittle, unit lower diagonal), returning
    the packed LU factors in one matrix."""
    n = a.shape[0]

    def outer(k, a):
        pivot = a[k, k]
        col = a[:, k] / pivot
        col = jnp.where(jnp.arange(n) > k, col, a[:, k])
        a = a.at[:, k].set(col)
        mask = (jnp.arange(n)[:, None] > k) & (jnp.arange(n)[None, :] > k)
        update = jnp.outer(col, a[k, :])
        a = jnp.where(mask, a - update, a)
        return a

    return jax.lax.fori_loop(0, n - 1, outer, a)


def fwd(diag, a):
    """Row-panel update: solve L X = A for X, with L = unit-lower(diag)."""
    n = a.shape[0]

    def body(k, x):
        factor = diag[:, k]  # L column k (unit diagonal below k)
        mask = jnp.arange(n)[:, None] > k
        x = jnp.where(mask, x - jnp.outer(factor, x[k, :]), x)
        return x

    return jax.lax.fori_loop(0, n, body, a)


def bdiv(diag, a):
    """Column-panel update: solve X U = A for X, with U = upper(diag)."""
    n = a.shape[0]

    def body(k, x):
        xk = x[:, k] / diag[k, k]
        x = x.at[:, k].set(xk)
        mask = jnp.arange(n)[None, :] > k
        x = jnp.where(mask, x - jnp.outer(xk, diag[k, :]), x)
        return x

    return jax.lax.fori_loop(0, n, body, a)


def bmod(row, col, inner):
    """Trailing update: inner -= row @ col."""
    return inner - row @ col


# ---------------------------------------------------------------------------
# Whole-problem references used by the integration tests.
# ---------------------------------------------------------------------------


def sparselu_blocked(blocks, nb):
    """Run the full blocked SparseLU elimination sequentially over a dict of
    blocks {(i, j): array}, with fill-in. Returns the updated dict."""
    blocks = dict(blocks)
    for kk in range(nb):
        blocks[(kk, kk)] = lu0(blocks[(kk, kk)])
        for jj in range(kk + 1, nb):
            if (kk, jj) in blocks:
                blocks[(kk, jj)] = fwd(blocks[(kk, kk)], blocks[(kk, jj)])
        for ii in range(kk + 1, nb):
            if (ii, kk) in blocks:
                blocks[(ii, kk)] = bdiv(blocks[(kk, kk)], blocks[(ii, kk)])
        for ii in range(kk + 1, nb):
            if (ii, kk) not in blocks:
                continue
            for jj in range(kk + 1, nb):
                if (kk, jj) not in blocks:
                    continue
                if (ii, jj) not in blocks:
                    blocks[(ii, jj)] = jnp.zeros_like(blocks[(kk, jj)])
                blocks[(ii, jj)] = bmod(
                    blocks[(ii, kk)], blocks[(kk, jj)], blocks[(ii, jj)]
                )
    return blocks
