"""L1 Pallas kernels: SparseLU block task bodies (paper §4.2.3).

The four kernels of the BOTS-derived benchmark:

* ``bmod`` — the flop-dominant trailing update ``inner -= row @ col``:
  a tiled, MXU-shaped Pallas GEMM with in-place accumulation, like the
  Matmul kernel.
* ``lu0`` / ``fwd`` / ``bdiv`` — panel factorizations/solves. A BS x BS f32
  block is at most 256 KiB (BS=256), so the whole block is VMEM-resident
  and the sequential elimination runs inside one kernel invocation — the
  TPU mapping of "the block fits in L2" that the CPU benchmark relies on.

interpret=True for CPU-PJRT executability (see matmul_block.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# --- bmod: tiled GEMM update ------------------------------------------------


def _bmod_kernel(row_ref, col_ref, inner_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = inner_ref[...]

    o_ref[...] -= jnp.dot(
        row_ref[...], col_ref[...], preferred_element_type=o_ref.dtype
    )


def bmod(row, col, inner, *, tile=128):
    """Trailing update: inner - row @ col (tiled for the MXU)."""
    bs = row.shape[0]
    t = min(tile, bs)
    assert bs % t == 0
    n = bs // t
    return pl.pallas_call(
        functools.partial(_bmod_kernel),
        grid=(n, n, n),
        in_specs=[
            pl.BlockSpec((t, t), lambda i, j, k: (i, k)),
            pl.BlockSpec((t, t), lambda i, j, k: (k, j)),
            pl.BlockSpec((t, t), lambda i, j, k: (i, j)),
        ],
        out_specs=pl.BlockSpec((t, t), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bs, bs), row.dtype),
        interpret=True,
    )(row, col, inner)


# --- VMEM-resident panel kernels ---------------------------------------------


def _lu0_kernel(a_ref, o_ref):
    n = a_ref.shape[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (n, n), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (n, n), 1)

    def body(k, a):
        pivot = jax.lax.dynamic_index_in_dim(
            jax.lax.dynamic_index_in_dim(a, k, 0, keepdims=False), k, 0, keepdims=False
        )
        col_k = jax.lax.dynamic_slice_in_dim(a, k, 1, axis=1)[:, 0]
        scaled = jnp.where(rows[:, 0] > k, col_k / pivot, col_k)
        a = jnp.where(cols == k, scaled[:, None], a)
        row_k = jax.lax.dynamic_slice_in_dim(a, k, 1, axis=0)[0, :]
        mask = (rows > k) & (cols > k)
        a = jnp.where(mask, a - scaled[:, None] * row_k[None, :], a)
        return a

    o_ref[...] = jax.lax.fori_loop(0, n - 1, body, a_ref[...])


def lu0(a):
    """In-block LU (Doolittle, unit lower), whole block VMEM-resident."""
    bs = a.shape[0]
    return pl.pallas_call(
        _lu0_kernel,
        out_shape=jax.ShapeDtypeStruct((bs, bs), a.dtype),
        interpret=True,
    )(a)


def _fwd_kernel(diag_ref, a_ref, o_ref):
    n = a_ref.shape[0]
    diag = diag_ref[...]
    rows = jax.lax.broadcasted_iota(jnp.int32, (n, n), 0)

    def body(k, x):
        factor = jax.lax.dynamic_slice_in_dim(diag, k, 1, axis=1)[:, 0]
        row_k = jax.lax.dynamic_slice_in_dim(x, k, 1, axis=0)[0, :]
        x = jnp.where(rows > k, x - factor[:, None] * row_k[None, :], x)
        return x

    o_ref[...] = jax.lax.fori_loop(0, n, body, a_ref[...])


def fwd(diag, a):
    """Row-panel update: solve L X = A, L = unit-lower(diag)."""
    bs = a.shape[0]
    return pl.pallas_call(
        _fwd_kernel,
        out_shape=jax.ShapeDtypeStruct((bs, bs), a.dtype),
        interpret=True,
    )(diag, a)


def _bdiv_kernel(diag_ref, a_ref, o_ref):
    n = a_ref.shape[0]
    diag = diag_ref[...]
    cols = jax.lax.broadcasted_iota(jnp.int32, (n, n), 1)

    def body(k, x):
        pivot = jax.lax.dynamic_index_in_dim(
            jax.lax.dynamic_index_in_dim(diag, k, 0, keepdims=False),
            k,
            0,
            keepdims=False,
        )
        col_k = jax.lax.dynamic_slice_in_dim(x, k, 1, axis=1)[:, 0] / pivot
        x = jnp.where(cols == k, col_k[:, None], x)
        row_k = jax.lax.dynamic_slice_in_dim(diag, k, 1, axis=0)[0, :]
        x = jnp.where(cols > k, x - col_k[:, None] * row_k[None, :], x)
        return x

    o_ref[...] = jax.lax.fori_loop(0, n, body, a_ref[...])


def bdiv(diag, a):
    """Column-panel update: solve X U = A, U = upper(diag)."""
    bs = a.shape[0]
    return pl.pallas_call(
        _bdiv_kernel,
        out_shape=jax.ShapeDtypeStruct((bs, bs), a.dtype),
        interpret=True,
    )(diag, a)
