"""L1 Pallas kernel: blocked-matmul task body (paper §4.2.1).

One Matmul task computes ``C += A @ B`` on a BS x BS block. The Pallas
kernel tiles the block for the MXU: ``tile x tile`` sub-blocks move through
VMEM on a (i, j, k) grid; the output tile is revisited across the k
dimension, accumulating in place (classic Pallas revisiting pattern — the
HBM<->VMEM schedule the paper's CPU code expressed through the cache
hierarchy; see DESIGN.md §Hardware-Adaptation).

VMEM footprint per grid step: 3 tiles x tile² x 4 B (tile=128 -> 192 KiB),
far under the 16 MiB/core budget; MXU sees (128, 128) f32 contractions.

interpret=True everywhere: real-TPU lowering emits Mosaic custom-calls the
CPU PJRT plugin cannot execute (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(a_ref, b_ref, c_ref, o_ref):
    """Grid (i, j, k): o[i, j] = c[i, j] + sum_k a[i, k] @ b[k, j]."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = c_ref[...]

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=o_ref.dtype
    )


def matmul_block(a, b, c, *, tile=128):
    """Pallas-tiled ``c + a @ b`` for square BS x BS blocks."""
    bs = a.shape[0]
    t = min(tile, bs)
    assert bs % t == 0, "BS must be a multiple of the tile size"
    n = bs // t
    return pl.pallas_call(
        functools.partial(_matmul_kernel),
        grid=(n, n, n),
        in_specs=[
            pl.BlockSpec((t, t), lambda i, j, k: (i, k)),
            pl.BlockSpec((t, t), lambda i, j, k: (k, j)),
            pl.BlockSpec((t, t), lambda i, j, k: (i, j)),
        ],
        out_specs=pl.BlockSpec((t, t), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bs, bs), a.dtype),
        interpret=True,
    )(a, b, c)
