"""L1 Pallas kernels: N-Body task bodies (paper §4.2.2).

``force(i, j)``: accelerations exerted by particle block j on block i
(softened gravity). The kernel tiles the *target* block across the grid;
the source block stays VMEM-resident (bs x 3 f32 = 1.5 KiB at bs=128), so
each grid step is a (tile_p x bs) pairwise sweep — the TPU analogue of the
cache-blocked inner loop of the CPU benchmark.

``update``: per-block integration, a pure element-wise kernel.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

SOFTENING = 1e-3


def _forces_kernel(pos_i_ref, pos_j_ref, mass_j_ref, o_ref):
    pos_i = pos_i_ref[...]  # (tp, 3)
    pos_j = pos_j_ref[...]  # (bs, 3)
    m = mass_j_ref[...]  # (bs,)
    d = pos_j[None, :, :] - pos_i[:, None, :]  # (tp, bs, 3)
    dist2 = jnp.sum(d * d, axis=-1) + SOFTENING
    inv_d3 = dist2 ** (-1.5)  # (tp, bs)
    w = inv_d3 * m[None, :]
    o_ref[...] = jnp.einsum("pq,pqc->pc", w, d)


def nbody_forces(pos_i, pos_j, mass_j, *, tile=64):
    """Accelerations on block i from block j: (bs, 3)."""
    bs = pos_i.shape[0]
    tp = min(tile, bs)
    assert bs % tp == 0
    return pl.pallas_call(
        functools.partial(_forces_kernel),
        grid=(bs // tp,),
        in_specs=[
            pl.BlockSpec((tp, 3), lambda i: (i, 0)),
            pl.BlockSpec((bs, 3), lambda i: (0, 0)),
            pl.BlockSpec((bs,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tp, 3), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bs, 3), pos_i.dtype),
        interpret=True,
    )(pos_i, pos_j, mass_j)


def _update_kernel(pos_ref, vel_ref, acc_ref, dt_ref, pos_o_ref, vel_o_ref):
    dt = dt_ref[0]
    vel_new = vel_ref[...] + acc_ref[...] * dt
    vel_o_ref[...] = vel_new
    pos_o_ref[...] = pos_ref[...] + vel_new * dt


def nbody_update(pos, vel, acc, dt):
    """Integrate one particle block. Returns (pos', vel')."""
    bs = pos.shape[0]
    dt_arr = jnp.asarray([dt], dtype=pos.dtype) if jnp.ndim(dt) == 0 else dt
    return pl.pallas_call(
        _update_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((bs, 3), pos.dtype),
            jax.ShapeDtypeStruct((bs, 3), vel.dtype),
        ),
        interpret=True,
    )(pos, vel, acc, dt_arr)
