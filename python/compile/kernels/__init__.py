"""L1 Pallas kernels (build-time only) + pure-jnp oracles (`ref`)."""

from . import ref  # noqa: F401
from .matmul_block import matmul_block  # noqa: F401
from .nbody_block import nbody_forces, nbody_update  # noqa: F401
from .sparselu_block import bdiv, bmod, fwd, lu0  # noqa: F401
