"""AOT compile path: lower every L2 model to HLO **text** artifacts.

Interchange format is HLO text, NOT ``lowered.compile()`` or serialized
``HloModuleProto``: jax >= 0.5 emits protos with 64-bit instruction ids
which xla_extension 0.5.1 (the version the published `xla` 0.1.6 rust crate
links) rejects; the text parser reassigns ids and round-trips cleanly.
See /opt/xla-example/README.md.

Usage: ``python -m compile.aot --out-dir ../artifacts`` (via `make
artifacts`). Python runs ONCE here; never on the execution path.
"""

import argparse
import hashlib
import os

import jax
from jax._src.lib import xla_client as xc

from .model import MODELS


def to_hlo_text(fn, arg_specs) -> str:
    lowered = jax.jit(fn).lower(*arg_specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", nargs="*", help="subset of model names")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    manifest_lines = []
    for name, (fn, specs) in sorted(MODELS.items()):
        if args.only and name not in args.only:
            continue
        text = to_hlo_text(fn, specs)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:16]
        shapes = ",".join(
            "x".join(map(str, s.shape)) + f":{s.dtype}" for s in specs
        )
        manifest_lines.append(f"{name}\t{digest}\t{shapes}")
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(args.out_dir, "MANIFEST.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"manifest: {len(manifest_lines)} artifacts")


if __name__ == "__main__":
    main()
